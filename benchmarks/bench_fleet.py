"""Fleet-scale scoring benchmark: plans-scored/sec and round latency.

Sweeps the plan-scoring core over K (pool size) x P (candidate count) and
each backend, then drives a real ``fleet-scale`` experiment end-to-end per K
to measure round latency. Writes ``BENCH_fleet.json`` so the perf
trajectory of the scoring core is tracked per-PR (CI runs ``--smoke``).

  PYTHONPATH=src python -m benchmarks.bench_fleet            # full sweep
  PYTHONPATH=src python -m benchmarks.bench_fleet --smoke    # CI-sized
  PYTHONPATH=src python -m benchmarks.bench_fleet --out BENCH_fleet.json
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core import scoring
from repro.core.plans import indices_to_plans, random_plan_indices

FULL_KS = [100, 1_000, 10_000, 100_000]
FULL_PS = [256, 4096]
SMOKE_KS = [100, 1_000]
SMOKE_PS = [64, 256]

KW = dict(alpha=4.0, beta=0.25, time_scale=3.0, fairness_scale=0.09,
          delta_fairness=True)


def _mem_budget_bytes() -> int:
    """~40% of physical RAM: the ceiling for dense-numpy scoring temporaries
    (the (P, K) float64 path peaks at ~32 bytes/element). Cells above the
    budget are skipped with a marker row instead of OOM-killing the sweep."""
    try:
        total = os.sysconf("SC_PHYS_PAGES") * os.sysconf("SC_PAGE_SIZE")
        return int(total * 0.4)
    except (ValueError, OSError, AttributeError):  # pragma: no cover
        return 6 << 30


def _time_call(fn, min_s: float = 0.3, max_reps: int = 50) -> tuple:
    fn()  # warm-up (jit compile + transfer paths)
    reps, t0 = 0, time.perf_counter()
    while True:
        fn()
        reps += 1
        elapsed = time.perf_counter() - t0
        if elapsed >= min_s or reps >= max_reps:
            break
    return elapsed / reps, reps


def bench_scoring(Ks, Ps, backends) -> list:
    """plans-scored/sec per (K, P, backend, plan form).

    ``dense`` scores (P, K) bool plans (what the per-scheduler numpy loops
    historically consumed); ``index`` scores the (P, n_sel) device-id form
    the vectorized candidate generators produce natively — the fleet fast
    path. ``speedup_vs_numpy`` is always relative to dense-numpy (the
    pre-refactor scoring path) at the same K, P.
    """
    rng = np.random.default_rng(0)
    budget = _mem_budget_bytes()
    rows = []
    for K in Ks:
        times = rng.uniform(1.0, 100.0, K)
        counts = rng.integers(0, 50, K).astype(np.float64)
        available = rng.random(K) < 0.9
        n_sel = max(1, K // 100)
        for P in Ps:
            idx = random_plan_indices(rng, available, n_sel, P)
            plans = indices_to_plans(idx, K)
            variants = [(b, "dense") for b in backends]
            variants += [("numpy", "index"), ("jax", "index")]
            base = None
            for backend, form in variants:
                if (backend == "numpy" and form == "dense"
                        and P * K * 32 > budget):
                    print(f"  K={K:>6} P={P:>5} {backend:>6}/{form:<5}: "
                          f"skipped (dense f64 temporaries exceed ~40% RAM)")
                    rows.append({"backend": backend, "form": form, "K": K,
                                 "P": P, "n_sel": n_sel, "skipped": True})
                    continue
                if form == "dense":
                    fn = lambda: scoring.score_plans(
                        times, counts, plans, backend=backend, **KW)
                else:
                    fn = lambda: scoring.score_plan_indices(
                        times, counts, idx, backend=backend, **KW)
                per_call, reps = _time_call(fn)
                r = {"backend": backend, "form": form, "K": K, "P": P,
                     "n_sel": n_sel, "reps": reps, "sec_per_call": per_call,
                     "plans_per_sec": P / per_call}
                if backend == "numpy" and form == "dense":
                    base = r["plans_per_sec"]
                r["speedup_vs_numpy"] = (r["plans_per_sec"] / base
                                         if base else None)
                rows.append(r)
                speedup = (f"x{r['speedup_vs_numpy']:.1f} vs numpy"
                           if r["speedup_vs_numpy"] is not None
                           else "baseline skipped")
                print(f"  K={K:>6} P={P:>5} {backend:>6}/{form:<5}: "
                      f"{r['plans_per_sec']:>12.0f} plans/s "
                      f"({r['sec_per_call'] * 1e3:.2f} ms/call, {speedup})")
    return rows


def bench_rounds(Ks, scheduler: str, backend: str, max_rounds: int) -> list:
    """End-to-end round latency through the experiment layer (fleet axis)."""
    from repro.experiment.presets import get_preset

    rows = []
    for K in Ks:
        spec = get_preset("fleet-scale", scheduler=scheduler, num_devices=K,
                          scoring_backend=backend, max_rounds=max_rounds)
        t0 = time.perf_counter()
        result = spec.run()
        wall = time.perf_counter() - t0
        n_rounds = len(result.records)
        sim_mean = float(np.mean(
            [v["mean_round_time"] for v in result.summary.values()]))
        rows.append({
            "K": K, "scheduler": scheduler, "backend": backend,
            "rounds": n_rounds, "wall_s": wall,
            "wall_s_per_round": wall / max(n_rounds, 1),
            "sim_mean_round_time_s": sim_mean,
        })
        print(f"  K={K:>6} {scheduler}/{backend}: {n_rounds} rounds in "
              f"{wall:.2f}s wall ({wall / max(n_rounds, 1) * 1e3:.0f} "
              f"ms/round), sim mean T={sim_mean:.1f}s")
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sweep (small K, fewer reps)")
    ap.add_argument("--out", default="BENCH_fleet.json")
    ap.add_argument("--scheduler", default="bods",
                    help="scheduler for the end-to-end round-latency sweep")
    args = ap.parse_args(argv)

    Ks = SMOKE_KS if args.smoke else FULL_KS
    Ps = SMOKE_PS if args.smoke else FULL_PS
    backends = ["numpy", "jax", "pallas"]

    print(f"== scoring core: plans-scored/sec (backends={backends}) ==")
    scoring_rows = bench_scoring(Ks, Ps, backends)

    round_Ks = [k for k in Ks if k <= 10_000]
    print("== end-to-end round latency (fleet-scale preset) ==")
    round_rows = bench_rounds(round_Ks, args.scheduler, "jax",
                              max_rounds=2 if args.smoke else 3)

    out = {
        "smoke": args.smoke,
        "jax_backend": scoring._jax_backend_name(),
        "Ks": Ks, "Ps": Ps,
        "scoring": scoring_rows,
        "rounds": round_rows,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
