"""Fleet-scale scoring benchmark: plans-scored/sec, round latency, sharding.

Sweeps the plan-scoring core over K (pool size) x P (candidate count) and
each backend, then drives a real ``fleet-scale`` experiment end-to-end per K
to measure round latency. Writes ``BENCH_fleet.json`` so the perf
trajectory of the scoring core is tracked per-PR (CI runs ``--smoke``).

Dense (P, K) arms are capped at ``DENSE_MAX_K`` devices: the K=1e6 arm
never materializes a dense membership matrix — above the cap only the
index form and the fleet-sharded path (``repro.core.shard``) run, with
candidates drawn in-graph by ``random_plan_indices_sharded``. Every arm
records its peak RSS (``VmHWM``, reset per arm via ``clear_refs``) so the
memory guard is visible in the JSON, not just the wall times.

``--shards N`` adds sharded arms (and re-execs through
``repro.launch.bootstrap`` so the host platform actually has N devices);
``--sharded-gate`` runs the CI regression gate instead of the full sweep:
single-lane vs shard_map at one K, gating score parity (<= 1e-5), sharded
throughput, and scaling efficiency.

  PYTHONPATH=src python -m benchmarks.bench_fleet            # full sweep
  PYTHONPATH=src python -m benchmarks.bench_fleet --shards 8 # + sharded arms
  PYTHONPATH=src python -m benchmarks.bench_fleet --smoke    # CI-sized
  PYTHONPATH=src python -m benchmarks.bench_fleet --sharded-gate --shards 4
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

# The host platform is sized at jax backend init (XLA_FLAGS), and
# repro.core.scoring imports jax at module import time — so peek at
# --shards and (maybe) re-exec BEFORE the heavy imports below.
from repro.launch.bootstrap import ensure_host_devices


def _peek_shards(argv) -> int:
    ap = argparse.ArgumentParser(add_help=False)
    ap.add_argument("--shards", type=int, default=1)
    ns, _ = ap.parse_known_args(argv)
    return max(1, ns.shards)


if __name__ == "__main__":
    ensure_host_devices(_peek_shards(sys.argv[1:]))  # may os.execve()

import numpy as np

from repro.core import scoring, shard
from repro.core.plans import indices_to_plans, random_plan_indices

FULL_KS = [100, 1_000, 10_000, 100_000, 1_000_000]
FULL_PS = [256, 4096]
SMOKE_KS = [100, 1_000]
SMOKE_PS = [64, 256]

# No dense (P, K) arm above this K, for ANY backend: at K=1e6 the bool
# membership matrix alone is P MB and the numpy f64 temporaries 32x that.
# Above the cap only index-form and sharded arms run, and candidates are
# drawn in-graph (sharded) instead of via the (P, |avail|) host key draw.
DENSE_MAX_K = 1 << 18

KW = dict(alpha=4.0, beta=0.25, time_scale=3.0, fairness_scale=0.09,
          delta_fairness=True)


def _mem_budget_bytes() -> int:
    """~40% of physical RAM: the ceiling for dense-numpy scoring temporaries
    (the (P, K) float64 path peaks at ~32 bytes/element). Cells above the
    budget are skipped with a marker row instead of OOM-killing the sweep."""
    try:
        total = os.sysconf("SC_PHYS_PAGES") * os.sysconf("SC_PAGE_SIZE")
        return int(total * 0.4)
    except (ValueError, OSError, AttributeError):  # pragma: no cover
        return 6 << 30


def _reset_peak_rss() -> None:
    """Reset the kernel's high-water RSS mark (VmHWM) so each arm records
    ITS OWN peak, not the process lifetime max. Linux-only; silently a
    no-op elsewhere (peak_rss_mb then reports the lifetime high water)."""
    try:
        with open("/proc/self/clear_refs", "w") as f:
            f.write("5")
    except OSError:
        pass


def _peak_rss_mb() -> float:
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmHWM:"):
                    return int(line.split()[1]) / 1024.0
    except OSError:
        pass
    return float("nan")


def _time_call(fn, min_s: float = 0.3, max_reps: int = 50) -> tuple:
    fn()  # warm-up (jit compile + transfer paths)
    reps, t0 = 0, time.perf_counter()
    while True:
        fn()
        reps += 1
        elapsed = time.perf_counter() - t0
        if elapsed >= min_s or reps >= max_reps:
            break
    return elapsed / reps, reps


def _make_candidates(rng, available, n_sel, P, shards):
    """(P, n_sel) candidate ids. Above DENSE_MAX_K the single-lane host
    draw would materialize a (P, |avail|) float64 key matrix (~29 GB at
    K=1e6, P=4096) — use the sharded in-graph draw there instead."""
    K = available.shape[0]
    if K > DENSE_MAX_K:
        return shard.random_plan_indices_sharded(
            rng, available, n_sel, P, num_shards=max(shards, 1))
    return random_plan_indices(rng, available, n_sel, P)


def bench_scoring(Ks, Ps, backends, shards: int = 1) -> list:
    """plans-scored/sec per (K, P, backend, plan form[, shard count]).

    ``dense`` scores (P, K) bool plans (what the per-scheduler numpy loops
    historically consumed); ``index`` scores the (P, n_sel) device-id form
    the vectorized candidate generators produce natively — the fleet fast
    path. ``speedup_vs_numpy`` is always relative to dense-numpy (the
    pre-refactor scoring path) at the same K, P. With ``shards > 1``,
    sharded arms ride along and record ``max_abs_diff_vs_single`` against
    the single-lane jax scores of the same form.
    """
    rng = np.random.default_rng(0)
    budget = _mem_budget_bytes()
    rows = []
    for K in Ks:
        times = rng.uniform(1.0, 100.0, K)
        counts = rng.integers(0, 50, K).astype(np.float64)
        available = rng.random(K) < 0.9
        n_sel = max(1, K // 100)
        for P in Ps:
            idx = _make_candidates(rng, available, n_sel, P, shards)
            plans = indices_to_plans(idx, K) if K <= DENSE_MAX_K else None
            variants = [(b, "dense", 1) for b in backends]
            variants += [("numpy", "index", 1), ("jax", "index", 1)]
            if shards > 1:
                if K <= DENSE_MAX_K:
                    variants.append(("jax", "dense", shards))
                variants.append(("jax", "index", shards))
            base = None
            single = {}  # form -> single-lane jax scores (parity reference)
            for backend, form, n_sh in variants:
                tag = f"{backend}/{form}" + (f"@{n_sh}" if n_sh > 1 else "")
                if form == "dense" and (
                        K > DENSE_MAX_K
                        or (backend == "numpy" and P * K * 32 > budget)):
                    why = ("dense arms capped at DENSE_MAX_K"
                           if K > DENSE_MAX_K
                           else "dense f64 temporaries exceed ~40% RAM")
                    print(f"  K={K:>7} P={P:>5} {tag:>14}: skipped ({why})")
                    rows.append({"backend": backend, "form": form, "K": K,
                                 "P": P, "n_sel": n_sel, "shards": n_sh,
                                 "skipped": True})
                    continue
                if form == "dense":
                    fn = lambda: scoring.score_plans(
                        times, counts, plans, backend=backend,
                        num_shards=n_sh, **KW)
                else:
                    fn = lambda: scoring.score_plan_indices(
                        times, counts, idx, backend=backend,
                        num_shards=n_sh, **KW)
                _reset_peak_rss()
                per_call, reps = _time_call(fn)
                r = {"backend": backend, "form": form, "K": K, "P": P,
                     "n_sel": n_sel, "shards": n_sh, "reps": reps,
                     "sec_per_call": per_call, "plans_per_sec": P / per_call,
                     "peak_rss_mb": round(_peak_rss_mb(), 1)}
                if form == "index":
                    r["ns_per_element"] = per_call / (P * n_sel) * 1e9
                if backend == "numpy" and form == "dense":
                    base = r["plans_per_sec"]
                r["speedup_vs_numpy"] = (r["plans_per_sec"] / base
                                         if base else None)
                if backend == "jax":
                    if n_sh == 1:
                        single[form] = fn()
                    elif form in single:
                        ref = single[form]
                        r["max_rel_diff_vs_single"] = float(np.max(
                            np.abs(fn() - ref) / np.maximum(np.abs(ref),
                                                            1e-12)))
                rows.append(r)
                speedup = (f"x{r['speedup_vs_numpy']:.1f} vs numpy"
                           if r["speedup_vs_numpy"] is not None
                           else "no dense-numpy baseline")
                print(f"  K={K:>7} P={P:>5} {tag:>14}: "
                      f"{r['plans_per_sec']:>12.0f} plans/s "
                      f"({r['sec_per_call'] * 1e3:.2f} ms/call, {speedup}, "
                      f"peak {r['peak_rss_mb']:.0f} MB)")
    return rows


def bench_rounds(Ks, scheduler: str, backend: str, max_rounds: int) -> list:
    """End-to-end round latency through the experiment layer (fleet axis)."""
    from repro.experiment.presets import get_preset

    rows = []
    for K in Ks:
        spec = get_preset("fleet-scale", scheduler=scheduler, num_devices=K,
                          scoring_backend=backend, max_rounds=max_rounds)
        t0 = time.perf_counter()
        result = spec.run()
        wall = time.perf_counter() - t0
        n_rounds = len(result.records)
        sim_mean = float(np.mean(
            [v["mean_round_time"] for v in result.summary.values()]))
        rows.append({
            "K": K, "scheduler": scheduler, "backend": backend,
            "rounds": n_rounds, "wall_s": wall,
            "wall_s_per_round": wall / max(n_rounds, 1),
            "sim_mean_round_time_s": sim_mean,
        })
        print(f"  K={K:>6} {scheduler}/{backend}: {n_rounds} rounds in "
              f"{wall:.2f}s wall ({wall / max(n_rounds, 1) * 1e3:.0f} "
              f"ms/round), sim mean T={sim_mean:.1f}s")
    return rows


def run_sharded_gate(args) -> dict:
    """CI gate: single-lane vs shard_map scoring at one (K, P).

    Gates (at ``--gate-k``, default 1e5, on the dense form — the one whose
    per-shard work actually shrinks by K/N):

    - parity: sharded scores within RELATIVE 1e-5 of single-lane (both
      forms; the single lane scores fully in f32 in-graph while the
      sharded path combines f32 partials in f64, so agreement is bounded
      by f32 resolution — relative, not absolute);
    - throughput: sharded plans/s >= ``--min-throughput-ratio`` x
      single-lane (the required ratio is halved when the machine has only
      one core — sharding cannot beat a lane it timeshares with);
    - scaling efficiency: speedup / N_eff >= ``--min-efficiency``, with
      N_eff = min(shards, cpu cores) — the shards that can actually run
      concurrently.
    """
    import jax

    N = args.shards
    if N < 2:
        raise SystemExit("--sharded-gate needs --shards >= 2")
    if jax.device_count() < N:
        raise SystemExit(
            f"--sharded-gate needs {N} host devices, found "
            f"{jax.device_count()} (launch via repro.launch.bootstrap or "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={N})")
    K, P = args.gate_k, 256
    n_eff = min(N, os.cpu_count() or 1)
    rng = np.random.default_rng(0)
    times = rng.uniform(1.0, 100.0, K)
    counts = rng.integers(0, 50, K).astype(np.float64)
    available = rng.random(K) < 0.9
    n_sel = max(1, K // 100)
    idx = _make_candidates(rng, available, n_sel, P, N)
    plans = indices_to_plans(idx, K) if K <= DENSE_MAX_K else None

    arms, failures = {}, []
    forms = (["dense", "index"] if plans is not None else ["index"])
    for form in forms:
        for n_sh in (1, N):
            if form == "dense":
                fn = lambda: scoring.score_plans(
                    times, counts, plans, backend="jax", num_shards=n_sh,
                    **KW)
            else:
                fn = lambda: scoring.score_plan_indices(
                    times, counts, idx, backend="jax", num_shards=n_sh, **KW)
            _reset_peak_rss()
            per_call, reps = _time_call(fn, min_s=0.5)
            arms[(form, n_sh)] = {
                "form": form, "shards": n_sh, "K": K, "P": P, "n_sel": n_sel,
                "reps": reps, "sec_per_call": per_call,
                "plans_per_sec": P / per_call,
                "peak_rss_mb": round(_peak_rss_mb(), 1),
                "scores": fn()}
            tag = f"jax/{form}" + (f"@{n_sh}" if n_sh > 1 else "")
            print(f"  K={K:>7} P={P:>5} {tag:>14}: "
                  f"{P / per_call:>12.0f} plans/s "
                  f"({per_call * 1e3:.2f} ms/call)")

    for form in forms:
        ref = arms[(form, 1)]["scores"]
        diff = float(np.max(np.abs(arms[(form, N)]["scores"] - ref)
                            / np.maximum(np.abs(ref), 1e-12)))
        arms[(form, N)]["max_rel_diff_vs_single"] = diff
        if diff > 1e-5:
            failures.append(f"{form}: sharded-vs-single relative score "
                            f"diff {diff:.2e} > 1e-5")

    gate_form = "dense" if "dense" in forms else "index"
    t1 = arms[(gate_form, 1)]["sec_per_call"]
    tn = arms[(gate_form, N)]["sec_per_call"]
    speedup = t1 / tn
    efficiency = speedup / n_eff
    req_ratio = (args.min_throughput_ratio if n_eff > 1
                 else args.min_throughput_ratio / 2)
    if speedup < req_ratio:
        failures.append(
            f"{gate_form}: sharded throughput x{speedup:.2f} of single-lane "
            f"< required x{req_ratio:.2f} (N_eff={n_eff})")
    if efficiency < args.min_efficiency:
        failures.append(
            f"{gate_form}: scaling efficiency {efficiency:.2f} "
            f"(speedup x{speedup:.2f} / N_eff={n_eff}) < "
            f"{args.min_efficiency}")
    print(f"  gate[{gate_form}]: speedup x{speedup:.2f}, efficiency "
          f"{efficiency:.2f} (N_eff={n_eff}), "
          f"{'FAIL' if failures else 'ok'}")

    for a in arms.values():
        del a["scores"]
    return {
        "mode": "sharded-gate", "shards": N, "n_eff": n_eff,
        "gate_form": gate_form, "jax_backend": scoring._jax_backend_name(),
        "device_count": int(jax.device_count()),
        "arms": list(arms.values()),
        "gate": {"speedup": speedup, "efficiency": efficiency,
                 "min_throughput_ratio": req_ratio,
                 "min_efficiency": args.min_efficiency,
                 "failures": failures},
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sweep (small K, fewer reps)")
    ap.add_argument("--out", default="BENCH_fleet.json")
    ap.add_argument("--scheduler", default="bods",
                    help="scheduler for the end-to-end round-latency sweep")
    ap.add_argument("--shards", type=int, default=1,
                    help="add fleet-sharded arms with this many shards "
                         "(re-execs via repro.launch.bootstrap so the host "
                         "platform has the devices)")
    ap.add_argument("--sharded-gate", action="store_true",
                    help="run the CI sharded-scoring regression gate "
                         "instead of the full sweep")
    ap.add_argument("--gate-k", type=int, default=100_000,
                    help="fleet size for --sharded-gate")
    ap.add_argument("--min-throughput-ratio", type=float, default=1.0,
                    help="gate: sharded plans/s >= this x single-lane "
                         "(halved automatically on single-core hosts)")
    ap.add_argument("--min-efficiency", type=float, default=0.5,
                    help="gate: speedup / N_eff >= this")
    args = ap.parse_args(argv)

    if args.sharded_gate:
        out = run_sharded_gate(args)
        with open(args.out, "w") as f:
            json.dump(out, f, indent=2)
        print(f"\nwrote {args.out}")
        if out["gate"]["failures"]:
            raise SystemExit("bench_fleet sharded gate FAILED:\n  "
                             + "\n  ".join(out["gate"]["failures"]))
        return

    Ks = SMOKE_KS if args.smoke else FULL_KS
    Ps = SMOKE_PS if args.smoke else FULL_PS
    backends = ["numpy", "jax", "pallas"]

    print(f"== scoring core: plans-scored/sec (backends={backends}, "
          f"shards={args.shards}) ==")
    scoring_rows = bench_scoring(Ks, Ps, backends, shards=args.shards)

    round_Ks = [k for k in Ks if k <= 10_000]
    print("== end-to-end round latency (fleet-scale preset) ==")
    round_rows = bench_rounds(round_Ks, args.scheduler, "jax",
                              max_rounds=2 if args.smoke else 3)

    out = {
        "smoke": args.smoke,
        "jax_backend": scoring._jax_backend_name(),
        "shards": args.shards,
        "dense_max_k": DENSE_MAX_K,
        "Ks": Ks, "Ps": Ps,
        "scoring": scoring_rows,
        "rounds": round_rows,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
