"""Paper Tables 1 & 2: convergence accuracy + time-to-target per scheduler,
Groups A and B, IID and non-IID (scheduler-plane benchmark on the calibrated
synthetic convergence model; the REAL-training variant is
``--real`` in benchmarks/bench_real_fl.py)."""

from __future__ import annotations

from benchmarks.common import GROUPS, SCHEDULERS, fmt_time, run_group


def run(group: str = "A", non_iid: bool = True, schedulers=None, seeds=(1, 2, 3)):
    import numpy as np

    schedulers = schedulers or SCHEDULERS
    dist = "non-IID" if non_iid else "IID"
    print(f"\n== Table {'1' if group == 'A' else '2'} (Group {group}, {dist}, "
          f"mean over {len(seeds)} seeds) ==")
    job_names = [s[0] for s in GROUPS[group]]
    header = f"{'method':8s} " + " ".join(f"{n:>18s}" for n in job_names)
    print(header + f"   {'makespan':>10s}   (best_acc / t2t_min)")
    rows = {}
    all_hit = {}
    for sched in schedulers:
        accs = {n: [] for n in job_names}
        t2ts = {n: [] for n in job_names}
        tt_makespans = []  # time at which ALL jobs reached their targets
        for seed in seeds:
            res = run_group(group, sched, non_iid, seed=seed)
            for name in job_names:
                v = res["summary"][name]
                accs[name].append(v["best_accuracy"])
                t2ts[name].append(v["time_to_target"])
            tt = [v["time_to_target"] for v in res["summary"].values()]
            tt_makespans.append(max(tt) if all(t is not None for t in tt)
                                else None)
        cells = []
        for name in job_names:
            hit = [t for t in t2ts[name] if t is not None]
            t2t = float(np.mean(hit)) if len(hit) == len(seeds) else None
            cells.append(f"{np.mean(accs[name]):.3f}/{fmt_time(t2t):>7s}")
            print(f"CSV,group{group},{dist},{sched},{name},"
                  f"{np.mean(accs[name]):.4f},"
                  f"{'' if t2t is None else f'{t2t:.0f}'}")
        ok = all(t is not None for t in tt_makespans)
        all_hit[sched] = ok
        rows[sched] = float(np.mean([t for t in tt_makespans if t is not None])) if ok else None
        mk = f"{rows[sched]/60:9.1f}m" if ok else "   (miss)"
        print(f"{sched:8s} " + " ".join(f"{c:>18s}" for c in cells) + f"   {mk}")
    # Rank only schedulers that hit EVERY job's target on EVERY seed —
    # finishing max_rounds fast while missing targets is not a win.
    qualified = {s: t for s, t in rows.items() if t is not None}
    if qualified and rows.get("random"):
        best = min(qualified, key=qualified.get)
        print(f"-> fastest all-targets makespan: {best} "
              f"({rows['random']/qualified[best]:.2f}x vs random)"
              + (f"; missed targets: {[s for s, ok in all_hit.items() if not ok]}"))
    return rows


def main():
    for group in ("A", "B"):
        for non_iid in (True, False):
            run(group, non_iid)


if __name__ == "__main__":
    main()
