"""Scheduler decision throughput: host vs fused search loops.

Sweeps scheduler x K (pool size) x search backend and measures DECISIONS
per second (one decision = one ``schedule(ctx)`` call on a fleet-realistic
context) plus the mean chosen-plan estimated cost at MATCHED search
budgets, then writes ``BENCH_sched.json`` so the perf trajectory of the
search subsystem (``repro/core/search.py``) is tracked per-PR.

Matched budgets: the fused arms are configured to spend exactly the same
number of cost evaluations per decision as the host arms (SA: 8 chains x
25 steps vs 200 sequential steps, with the cooling rate raised to the 8th
power so each short chain spans the same temperature range; GA/BODS: same
population/candidate knobs), so the recorded ``mean_cost`` columns are
directly comparable — the regression gate requires fused decisions to be
at least as good AND at least as fast as host ones.

``--shards N`` adds informational fused arms with the fleet axis sharded
across N host platform devices (``CostModel.num_shards`` -> the fused
searchers' shard_map chains; re-execs via ``repro.launch.bootstrap`` so
the devices exist). These rows are NOT gated — chain partitioning is
bitwise-identical to single-lane by construction, so the arms only track
the dispatch overhead / speedup of the sharded search path.

  PYTHONPATH=src python -m benchmarks.bench_sched            # full sweep
  PYTHONPATH=src python -m benchmarks.bench_sched --smoke    # CI-sized
  PYTHONPATH=src python -m benchmarks.bench_sched --shards 4 # + sharded arms
"""

from __future__ import annotations

import argparse
import json
import sys
import time

# Size the host platform before anything imports jax (see bench_fleet).
from repro.launch.bootstrap import ensure_host_devices


def _peek_shards(argv) -> int:
    ap = argparse.ArgumentParser(add_help=False)
    ap.add_argument("--shards", type=int, default=1)
    ns, _ = ap.parse_known_args(argv)
    return max(1, ns.shards)


if __name__ == "__main__":
    ensure_host_devices(_peek_shards(sys.argv[1:]))  # may os.execve()

import numpy as np

from repro.core.cost import CostModel
from repro.core.devices import DevicePool
from repro.core.schedulers import get_scheduler
from repro.core.schedulers.base import SchedulingContext

FULL_KS = [100, 1_000, 10_000]
SMOKE_KS = [100, 1_000]
SEARCHERS = ["sa", "genetic", "bods"]
BASELINES = ["greedy", "fedcs"]
# Throughput + cost gates apply to the searchers whose objective IS the
# chosen-plan cost; BODS is gated on cost parity only (no throughput
# gate), with a looser tolerance: its decisions are EI-driven
# (exploration is part of the objective), so chosen-plan cost parity with
# the host path is statistical rather than monotone.
GATED = ["sa", "genetic"]
BODS_COST_TOL = 1.10

SA_BUDGET = 200          # host: 200 sequential steps
SA_CHAINS = 8            # fused: 8 chains x 25 steps == the same budget


def search_kwargs(name: str, backend: str) -> dict:
    if name != "sa":
        return {}
    if backend == "host":
        return {"steps": SA_BUDGET}
    steps = SA_BUDGET // SA_CHAINS
    return {"steps": steps, "chains": SA_CHAINS,
            "cooling": 0.97 ** SA_CHAINS}


def make_scenario(K: int, seed: int, num_shards: int = 1):
    """A fleet-realistic decision point: 20% of the pool busy, non-trivial
    cumulative counts, calibrated cost normalizers."""
    n_sel = max(1, K // 100)
    pool = DevicePool.heterogeneous(K, 2, seed=seed)
    cm = CostModel(pool, alpha=4.0, beta=0.25, num_shards=num_shards)
    cm.calibrate([5.0, 5.0], n_sel=n_sel)
    rng = np.random.default_rng(seed + 1000)
    counts = rng.integers(0, 8, K).astype(np.float64)
    avail = np.ones(K, bool)
    avail[rng.choice(K, K // 5, replace=False)] = False
    times = pool.expected_times(0, 5.0)

    def ctx():
        return SchedulingContext(
            job=0, round_idx=0, tau=5.0, n_sel=n_sel,
            available=avail.copy(), counts=counts.copy(),
            expected_times=times)

    return cm, ctx, n_sel


def bench_decisions(name: str, backend: str, K: int, seed: int = 0,
                    min_s: float = 1.0, max_reps: int = 200,
                    num_shards: int = 1) -> dict:
    cm, ctx, n_sel = make_scenario(K, seed, num_shards=num_shards)
    kw = search_kwargs(name, backend)
    if name in SEARCHERS:
        kw["search_backend"] = backend
    sched = get_scheduler(name, cost_model=cm, seed=seed, **kw)
    sched.schedule(ctx())  # warm-up: jit compile + BODS bootstrap
    sched.schedule(ctx())
    costs, reps = [], 0
    t0 = time.perf_counter()
    while True:
        sched.schedule(ctx())
        costs.append(sched.last_estimated_cost)
        reps += 1
        elapsed = time.perf_counter() - t0
        if elapsed >= min_s or reps >= max_reps:
            break
    return {"scheduler": name, "backend": backend, "K": K, "n_sel": n_sel,
            "shards": num_shards,
            "reps": reps, "sec_per_decision": elapsed / reps,
            "decisions_per_sec": reps / elapsed,
            "mean_cost": float(np.mean(costs))}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sweep (small K, fewer reps)")
    ap.add_argument("--out", default="BENCH_sched.json")
    ap.add_argument("--min-speedup", type=float, default=1.0,
                    help="fail if fused decisions/sec < this multiple of "
                         "host at the largest K (CI uses 1.0 — no "
                         "regression vs host; full runs report >=10x)")
    ap.add_argument("--cost-tol", type=float, default=1.005,
                    help="fail if fused mean chosen-plan cost exceeds "
                         "host mean * this factor at matched budgets")
    ap.add_argument("--shards", type=int, default=1,
                    help="add informational fused arms with the fleet axis "
                         "sharded over this many host devices (not gated)")
    args = ap.parse_args(argv)

    Ks = SMOKE_KS if args.smoke else FULL_KS
    min_s = 0.5 if args.smoke else 1.5

    rows = []
    print("== scheduler decision throughput (host vs fused) ==")
    for K in Ks:
        for name in SEARCHERS:
            pair = {}
            for backend in ("host", "fused"):
                r = bench_decisions(name, backend, K, min_s=min_s)
                pair[backend] = r
                rows.append(r)
            h, f = pair["host"], pair["fused"]
            f["speedup_vs_host"] = f["decisions_per_sec"] / h["decisions_per_sec"]
            print(f"  K={K:>6} {name:>8}: host {h['decisions_per_sec']:8.2f}"
                  f" dec/s (cost {h['mean_cost']:.4f})  fused "
                  f"{f['decisions_per_sec']:8.2f} dec/s (cost "
                  f"{f['mean_cost']:.4f})  x{f['speedup_vs_host']:.1f}")
            if args.shards > 1:
                s = bench_decisions(name, "fused", K, min_s=min_s,
                                    num_shards=args.shards)
                s["speedup_vs_host"] = (s["decisions_per_sec"]
                                        / h["decisions_per_sec"])
                rows.append(s)
                print(f"  K={K:>6} {name:>8}: fused@{args.shards} "
                      f"{s['decisions_per_sec']:8.2f} dec/s (cost "
                      f"{s['mean_cost']:.4f})  x{s['speedup_vs_host']:.1f}")
        for name in BASELINES:
            r = bench_decisions(name, "host", K, min_s=min_s)
            rows.append(r)
            print(f"  K={K:>6} {name:>8}: {r['decisions_per_sec']:8.2f} "
                  f"dec/s (cost {r['mean_cost']:.4f})")

    # ---- regression gates (largest K of the sweep) ----
    K_gate = Ks[-1]
    failures = []
    for name in GATED + ["bods"]:
        h = next(r for r in rows if r["scheduler"] == name
                 and r["backend"] == "host" and r["K"] == K_gate)
        f = next(r for r in rows if r["scheduler"] == name
                 and r["backend"] == "fused" and r["K"] == K_gate
                 and r.get("shards", 1) == 1)
        if name in GATED:
            speedup = f["decisions_per_sec"] / h["decisions_per_sec"]
            if speedup < args.min_speedup:
                failures.append(
                    f"{name}: fused x{speedup:.2f} < required "
                    f"x{args.min_speedup:.2f} vs host at K={K_gate}")
        tol = args.cost_tol if name in GATED else BODS_COST_TOL
        if f["mean_cost"] > h["mean_cost"] * tol:
            failures.append(
                f"{name}: fused mean cost {f['mean_cost']:.4f} > host "
                f"{h['mean_cost']:.4f} * {tol} at K={K_gate} "
                "(matched budgets)")

    out = {
        "smoke": args.smoke,
        "Ks": Ks,
        "sa_budget": {"host_steps": SA_BUDGET, "fused_chains": SA_CHAINS,
                      "fused_steps": SA_BUDGET // SA_CHAINS},
        "decisions": rows,
        "gate": {"min_speedup": args.min_speedup,
                 "cost_tol": args.cost_tol, "K": K_gate,
                 "failures": failures},
    }
    with open(args.out, "w") as fobj:
        json.dump(out, fobj, indent=2)
    print(f"\nwrote {args.out}")
    if failures:
        raise SystemExit("bench_sched regression gate FAILED:\n  "
                         + "\n  ".join(failures))


if __name__ == "__main__":
    main()
