"""§Roofline table: read the dry-run JSON and emit the per-cell terms.

Falls back to a clear message if the dry-run has not been executed
(``python -m repro.launch.dryrun --all``).
"""

from __future__ import annotations

import json
import os

DRYRUN_JSON = os.path.join(os.path.dirname(__file__), "..", "dryrun_results.json")


def main(path: str = DRYRUN_JSON):
    if not os.path.exists(path):
        print("roofline: dryrun_results.json missing — run "
              "`python -m repro.launch.dryrun --all` first")
        return
    with open(path) as f:
        d = json.load(f)
    print("\n== Roofline (single-pod 16x16, per-device terms in seconds) ==")
    print(f"{'arch':18s} {'shape':12s} {'compute':>9s} {'memory':>9s} "
          f"{'coll':>9s} {'dominant':>10s} {'roofl%':>7s} {'useful':>7s}")
    for k in sorted(d):
        v = d[k]
        if v.get("status") != "ok" or v.get("mesh") != "single":
            continue
        r = v["roofline"]
        print(f"{v['arch']:18s} {v['shape']:12s} {r['compute_s']:9.4f} "
              f"{r['memory_s']:9.4f} {r['collective_s']:9.4f} "
              f"{r['dominant']:>10s} {r['roofline_fraction']*100:6.2f}% "
              f"{r['useful_flops_ratio']:7.2f}")
        print(f"CSV,roofline,{v['arch']},{v['shape']},{r['compute_s']:.6f},"
              f"{r['memory_s']:.6f},{r['collective_s']:.6f},{r['dominant']},"
              f"{r['roofline_fraction']:.4f}")
    n_multi = sum(1 for v in d.values()
                  if v.get("status") == "ok" and v.get("mesh") == "multi")
    print(f"(multi-pod mesh: {n_multi} cells compiled OK — §Dry-run)")


if __name__ == "__main__":
    main()
