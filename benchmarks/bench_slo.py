"""SLO resilience benchmark: the degradation ladder must be cheap, honest,
and inert when asked to be.

Four measurements, written to ``BENCH_slo.json`` (gates enforced in CI
bench-smoke):

1. **Inert parity** — a spec whose ``slo`` axis is present but all-default
   must produce a round-record trajectory BIT-IDENTICAL to the same spec
   with no ``slo`` axis at all (``effective_slo`` treats it as absent).
2. **Governor overhead** — the same seeded quickstart workload with an
   attached-but-never-degrading governor (``max_queue_depth`` huge, no
   deadline) vs ungoverned, interleaved trial-by-trial with alternating
   order; the paired-median overhead must stay <= ``--max-overhead`` unless
   the absolute difference is below the timing-noise floor. The governed
   records must match the ungoverned ones on every field except the
   governor's own annotations (``rung``).
3. **Deadline compliance** — an overloaded service run (``slo-overload``
   preset) with a wall-clock ``decision_deadline_ms``: after a warmup run
   (jit compile outside the measurement), EVERY decision must land within
   the deadline at whatever rung the governor picked.
4. **Degraded-plan quality + bounded shedding** — for the degraded
   decisions of (3), the chosen plan's Formula-2 cost on the SAME context
   must stay <= ``--max-cost-ratio`` x the full search's plan cost, and
   the shed fraction of arrivals must stay <= ``--max-shed-frac``.

  PYTHONPATH=src python -m benchmarks.bench_slo           # full size
  PYTHONPATH=src python -m benchmarks.bench_slo --smoke   # CI-sized
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import numpy as np

# Fields the governor itself stamps on records — the overhead arm compares
# trajectories modulo these (an attached governor annotates rung="full";
# an ungoverned run records None).
_GOVERNOR_FIELDS = ("rung",)


def _quickstart(max_rounds: int):
    from repro.experiment.presets import get_preset

    spec = get_preset("quickstart")
    return spec.replace(jobs=tuple(
        dataclasses.replace(j, max_rounds=max_rounds, target_metric=2.0)
        for j in spec.jobs))


def _timed_run(spec):
    ex = spec.build()
    t0 = time.perf_counter()
    res = ex.run()
    return time.perf_counter() - t0, res.records


def _records_identical(a, b, ignore=()) -> bool:
    if len(a) != len(b):
        return False
    for ra, rb in zip(a, b):
        da, db = dataclasses.asdict(ra), dataclasses.asdict(rb)
        for k, va in da.items():
            if k in ignore:
                continue
            vb = db[k]
            if isinstance(va, np.ndarray):
                if not np.array_equal(va, vb):
                    return False
            elif va != vb and not (va is None and vb is None):
                return False
    return True


def bench_inert(max_rounds: int) -> dict:
    """An all-default ``slo`` axis must change NOTHING."""
    spec = _quickstart(max_rounds)
    _, recs_off = _timed_run(spec)
    _, recs_inert = _timed_run(spec.replace(slo={}))
    return {"rounds": len(recs_off),
            "records_identical": _records_identical(recs_off, recs_inert)}


def bench_overhead(max_rounds: int, trials: int) -> dict:
    """Attached-but-idle governor vs none, paired and order-alternated.
    ``max_queue_depth`` huge + no deadline => queue depth 0 keeps every
    decision at the full rung, so the plans must be identical and the
    timing difference is pure governor bookkeeping."""
    spec_off = _quickstart(max_rounds)
    spec_on = spec_off.replace(slo={"max_queue_depth": 1_000_000})

    _timed_run(spec_off)  # warm the jit caches outside the timing

    t_off, t_on = [], []
    identical = True
    for t in range(trials):
        arms = [(spec_off, t_off), (spec_on, t_on)]
        if t % 2:
            arms.reverse()
        recs = {}
        for spec, bucket in arms:
            dt, r = _timed_run(spec)
            bucket.append(dt)
            recs[spec is spec_on] = r
        identical = identical and _records_identical(
            recs[False], recs[True], ignore=_GOVERNOR_FIELDS)
    ratios = np.asarray(t_on) / np.asarray(t_off)
    return {"ungoverned_s": float(np.median(t_off)),
            "governed_s": float(np.median(t_on)),
            "overhead": float(np.median(ratios)) - 1.0,
            "diff_s": float(np.median(t_on) - np.median(t_off)),
            "records_identical": identical,
            "trials": trials, "rounds_per_run": max_rounds}


def bench_ladder(deadline_ms: float, smoke: bool, max_scored: int) -> dict:
    """Overloaded service under a wall-clock deadline: compliance, degraded
    plan quality vs the full search on the same contexts, shed fraction."""
    from repro.experiment.presets import get_preset
    from repro.serve.service import SchedulerService
    from repro.serve.traffic import trace_from_spec

    kwargs = dict(horizon=6_000.0, num_devices=30) if smoke else {}
    spec = get_preset("slo-overload", **kwargs)
    spec = spec.replace(slo={"decision_deadline_ms": deadline_ms})
    service = SchedulerService(spec)
    trace = trace_from_spec(spec.arrivals, len(service.templates),
                            service.engine.pool.num_devices)

    service.run(trace)  # warmup: jit compile of the full search

    service = SchedulerService(spec)
    service.engine.governor.keep_decisions = True
    report = service.run(trace)
    gov = service.engine.governor
    log = gov.decision_log

    within = sum(1 for d in log if d["ms"] <= deadline_ms)
    degraded = [d for d in log if d["rung"] != "full"]

    # Re-score a bounded sample of degraded decisions against the full
    # search on the very same (post-masking) contexts.
    scheduler = service.engine.scheduler
    cost_model = service.engine.cost_model
    ratios = []
    for d in degraded[:max_scored]:
        ctx = d["ctx"]
        chosen = float(np.asarray(cost_model.cost_indices(
            ctx.expected_times, ctx.counts, d["idx"][None]))[0])
        full_idx = np.flatnonzero(scheduler.schedule(ctx))
        full = float(np.asarray(cost_model.cost_indices(
            ctx.expected_times, ctx.counts, full_idx[None]))[0])
        if full > 0:
            ratios.append(chosen / full)
    res = report.resilience or {}
    shed = int(res.get("shed_arrivals", 0))
    return {
        "deadline_ms": deadline_ms,
        "decisions": len(log),
        "within_deadline": within,
        "within_deadline_frac": within / len(log) if log else 0.0,
        "rung_counts": dict(gov.rung_counts),
        "degraded_decisions": len(degraded),
        "scored": len(ratios),
        "max_cost_ratio": float(max(ratios)) if ratios else None,
        "median_cost_ratio": float(np.median(ratios)) if ratios else None,
        "arrivals": int(report.arrivals),
        "shed_arrivals": shed,
        "deferrals": int(res.get("deferrals", 0)),
        "shed_frac": shed / report.arrivals if report.arrivals else 0.0,
        "breaker_trips": int(res.get("breaker_trips", 0)),
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer trials/rounds, short horizon)")
    ap.add_argument("--out", default="BENCH_slo.json")
    ap.add_argument("--max-overhead", type=float, default=0.05,
                    help="fail if the idle governor costs more than this "
                         "fraction of the ungoverned run (median paired)")
    ap.add_argument("--noise-floor-s", type=float, default=0.05,
                    help="absolute per-run difference below which the "
                         "overhead gate cannot fail (timing noise)")
    ap.add_argument("--deadline-ms", type=float, default=250.0,
                    help="wall-clock decision deadline for the ladder arm "
                         "(generous: gates on 100%% compliance, warm jit)")
    ap.add_argument("--max-cost-ratio", type=float, default=2.0,
                    help="fail if any scored degraded decision's plan cost "
                         "exceeds this multiple of the full search's")
    ap.add_argument("--max-shed-frac", type=float, default=0.5,
                    help="fail if more than this fraction of arrivals is "
                         "shed under overload")
    ap.add_argument("--max-scored", type=int, default=200,
                    help="cap on degraded decisions re-scored in arm 4")
    args = ap.parse_args(argv)

    max_rounds, trials = (40, 5) if args.smoke else (80, 9)

    print("== inert parity (slo axis all-default vs absent) ==")
    inert = bench_inert(max_rounds)
    print(f"  {inert['rounds']} rounds  "
          f"records identical={inert['records_identical']}")

    print("== idle-governor overhead (paired, order-alternated) ==")
    ov = bench_overhead(max_rounds, trials)
    print(f"  ungoverned {ov['ungoverned_s'] * 1e3:8.1f}ms/run  "
          f"governed {ov['governed_s'] * 1e3:8.1f}ms/run  "
          f"overhead {ov['overhead'] * 100:+.2f}%  "
          f"records identical={ov['records_identical']}")

    print(f"== degradation ladder under overload "
          f"(deadline {args.deadline_ms:.0f}ms) ==")
    lad = bench_ladder(args.deadline_ms, args.smoke, args.max_scored)
    hist = " ".join(f"{k}={v}" for k, v in lad["rung_counts"].items() if v)
    print(f"  {lad['decisions']} decisions, rungs[{hist}]")
    print(f"  within deadline {lad['within_deadline']}/{lad['decisions']}  "
          f"shed {lad['shed_arrivals']}/{lad['arrivals']} "
          f"(deferred {lad['deferrals']})")
    if lad["scored"]:
        print(f"  degraded plan cost vs full search over {lad['scored']} "
              f"contexts: median x{lad['median_cost_ratio']:.3f} "
              f"max x{lad['max_cost_ratio']:.3f}")

    failures = []
    if not inert["records_identical"]:
        failures.append("inert slo axis perturbed the trajectory")
    if not ov["records_identical"]:
        failures.append("idle governor changed the chosen plans (records "
                        "diverged beyond the rung annotation)")
    if ov["overhead"] > args.max_overhead and ov["diff_s"] > args.noise_floor_s:
        failures.append(f"governor overhead {ov['overhead'] * 100:.2f}% > "
                        f"{args.max_overhead * 100:.0f}% gate "
                        f"(diff {ov['diff_s'] * 1e3:.1f}ms above the "
                        f"{args.noise_floor_s * 1e3:.0f}ms noise floor)")
    if lad["within_deadline_frac"] < 1.0:
        failures.append(
            f"{lad['decisions'] - lad['within_deadline']} of "
            f"{lad['decisions']} decisions missed the "
            f"{args.deadline_ms:.0f}ms deadline at their recorded rung")
    if lad["degraded_decisions"] == 0:
        failures.append("overload run never degraded — ladder inert, "
                        "quality gate vacuous")
    if lad["max_cost_ratio"] is not None \
            and lad["max_cost_ratio"] > args.max_cost_ratio:
        failures.append(f"degraded plan cost x{lad['max_cost_ratio']:.2f} "
                        f"> x{args.max_cost_ratio:.1f} of full search")
    if lad["shed_frac"] > args.max_shed_frac:
        failures.append(f"shed fraction {lad['shed_frac']:.2f} > "
                        f"{args.max_shed_frac:.2f} gate")

    out = {"smoke": args.smoke, "inert": inert, "overhead": ov,
           "ladder": lad,
           "gate": {"max_overhead": args.max_overhead,
                    "noise_floor_s": args.noise_floor_s,
                    "deadline_ms": args.deadline_ms,
                    "max_cost_ratio": args.max_cost_ratio,
                    "max_shed_frac": args.max_shed_frac,
                    "failures": failures}}
    with open(args.out, "w") as fobj:
        json.dump(out, fobj, indent=2)
    print(f"\nwrote {args.out}")
    if failures:
        raise SystemExit("bench_slo regression gate FAILED:\n  "
                         + "\n  ".join(failures))


if __name__ == "__main__":
    main()
