"""Benchmark entrypoint: one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all tables
  PYTHONPATH=src python -m benchmarks.run --only groups,roofline

Emits human tables + machine CSV lines (prefix "CSV,").
Table map: groups -> paper Tables 1-2 (+Figs 3,5,6,7 trajectories as CSV),
mj_vs_sj -> Table 5, ablation -> appendix fairness ablation,
roofline -> EXPERIMENTS.md §Roofline source data,
fleet -> BENCH_fleet.json (plan-scoring core perf, smoke-sized here;
run benchmarks.bench_fleet directly for the full K=1e5 sweep).

Every engine-backed section is spec-driven: each cell is a declarative
``repro.experiment.ExperimentSpec`` (see ``benchmarks/common.py``), so any
table cell can be re-run standalone, e.g.:

  PYTHONPATH=src python -m repro.experiment.cli preset paper-group-a \\
      --arg scheduler=rlds --arg non_iid=true --run
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="groups,mj_vs_sj,ablation,roofline,fleet")
    args = ap.parse_args()
    picks = set(args.only.split(","))
    t0 = time.time()

    if "groups" in picks:
        from benchmarks import bench_groups
        bench_groups.main()
    if "mj_vs_sj" in picks:
        from benchmarks import bench_multijob_vs_single
        bench_multijob_vs_single.main()
    if "ablation" in picks:
        from benchmarks import bench_ablation
        bench_ablation.main()
    if "roofline" in picks:
        from benchmarks import bench_roofline
        bench_roofline.main()
    if "fleet" in picks:
        from benchmarks import bench_fleet
        bench_fleet.main(["--smoke"])

    print(f"\nall benchmarks done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
