"""Fault-tolerance benchmark: robust-aggregation overhead and screening
parity.

Three measurements, written to ``BENCH_faults.json`` (gates enforced in CI
bench-smoke):

1. **Robust overhead** — the same fused FL workload run through
   ``FusedMultiRuntime`` with ``robust=False`` vs ``robust=True`` (no
   corruption injected, so the trajectories must stay numerically
   IDENTICAL). The in-jit screening (finite check + masked-median norm
   test + guarded FedAvg) must cost <= ``--max-overhead`` (default 5%)
   median per-round wall time.
2. **Rejection parity** — the jitted ``rejection_mask`` vs the numpy
   ``rejection_mask_host`` reference over randomized cohorts with NaN
   lanes, norm outliers, and zero-weight padding: zero mismatches allowed.
3. **Chaos completion** — the ``fault-injection`` preset (dropouts +
   crashes + stragglers + domain outages + corrupted uploads) must finish
   with every recorded metric finite, and must actually have injected
   faults (dropped > 0, corrupt > 0).

  PYTHONPATH=src python -m benchmarks.bench_faults           # full size
  PYTHONPATH=src python -m benchmarks.bench_faults --smoke   # CI-sized
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import numpy as np

PyTree = dict


def _setup_fused(num_devices: int, samples: int, seed: int = 0):
    from repro.config.base import JobConfig
    from repro.configs.paper_models import lenet5
    from repro.data.synthetic import make_classification_dataset
    from repro.fl.partition import noniid_partition

    cfg = dataclasses.replace(
        lenet5(), name="bench", input_shape=(16, 16, 1),
        cnn_spec=(("convp", 8, 3), ("convp", 16, 3), ("flatten",),
                  ("fc", 64)))
    x, y = make_classification_dataset(samples, cfg.input_shape,
                                       cfg.num_classes, noise=1.0, seed=seed)
    ex, ey = make_classification_dataset(120, cfg.input_shape,
                                         cfg.num_classes, noise=1.0,
                                         seed=seed + 50)
    part = noniid_partition(y, num_devices, seed=seed)
    job = JobConfig(job_id=0, model=cfg, target_metric=2.0,
                    local_epochs=5, batch_size=8, lr=0.05)
    return [job], [(x, y, part, ex, ey)]


def bench_overhead(num_devices: int, samples: int, rounds: int,
                   warmup: int) -> dict:
    """Interleave plain and robust runtimes round-by-round (alternating
    which goes first) so machine drift hits both equally; the overhead is
    the median of the per-round paired ratios."""
    from repro.fl.runtime import FusedMultiRuntime

    rng = np.random.default_rng(7)
    cohorts = [rng.choice(num_devices, 8, replace=False)
               for _ in range(rounds + warmup)]

    jobs, datasets = _setup_fused(num_devices, samples)
    plain = FusedMultiRuntime(jobs, datasets, seed=0)
    jobs, datasets = _setup_fused(num_devices, samples)
    robust = FusedMultiRuntime(jobs, datasets, seed=0, robust=True)

    def timed(rt, ids, r):
        t0 = time.perf_counter()
        m = rt.run_round(0, ids, r)
        return time.perf_counter() - t0, m

    t_plain, t_robust, max_diff = [], [], 0.0
    for r, ids in enumerate(cohorts):
        pair = [(plain, t_plain), (robust, t_robust)]
        if r % 2:
            pair.reverse()
        out = {}
        for rt, bucket in pair:
            dt, m = timed(rt, ids, r)
            if r >= warmup:
                bucket.append(dt)
            out[rt is robust] = m
        # With no corruption injected the robust path must change NOTHING.
        max_diff = max(max_diff,
                       abs(out[True]["loss"] - out[False]["loss"])
                       + abs(out[True]["accuracy"] - out[False]["accuracy"]))
    ratios = np.asarray(t_robust) / np.asarray(t_plain)
    return {"plain_round_s": float(np.median(t_plain)),
            "robust_round_s": float(np.median(t_robust)),
            "overhead": float(np.median(ratios)) - 1.0,
            "metric_max_diff": max_diff, "rounds": rounds}


def bench_rejection_parity(trials: int) -> dict:
    import jax.numpy as jnp

    from repro.fl.aggregation import rejection_mask, rejection_mask_host

    rng = np.random.default_rng(11)
    mismatches = 0
    for _ in range(trials):
        n, d = int(rng.integers(4, 17)), int(rng.integers(3, 33))
        g = {"w": rng.normal(size=(d,)).astype(np.float32),
             "b": rng.normal(size=(2, d)).astype(np.float32)}
        s = {"w": g["w"][None] + 0.1 * rng.normal(size=(n, d)).astype(
                np.float32),
             "b": g["b"][None] + 0.1 * rng.normal(size=(n, 2, d)).astype(
                np.float32)}
        w = rng.uniform(0.5, 2.0, size=n).astype(np.float32)
        w[rng.random(n) < 0.2] = 0.0                        # bucket padding
        for i in range(n):                                  # inject faults
            u = rng.random()
            if u < 0.15:
                s["w"][i] = np.nan
            elif u < 0.3:
                s["b"][i] *= 100.0                          # norm outlier
        mult = float(rng.uniform(2.0, 6.0))
        host = rejection_mask_host(g, s, w, mult)
        fused = np.asarray(rejection_mask(g, s, jnp.asarray(w),
                                          jnp.float32(mult)))
        mismatches += int((host != fused).sum())
    return {"trials": trials, "mismatches": mismatches}


def bench_chaos_preset(num_devices: int, max_rounds: int) -> dict:
    from repro.experiment.presets import get_preset

    spec = get_preset("fault-injection", scheduler="random",
                      num_devices=num_devices)
    spec = spec.replace(jobs=tuple(
        dataclasses.replace(j, max_rounds=max_rounds) for j in spec.jobs))
    t0 = time.perf_counter()
    res = spec.run()
    wall = time.perf_counter() - t0
    finite = all(np.isfinite(r.accuracy) and np.isfinite(r.loss)
                 and np.isfinite(r.round_time) for r in res.records)
    dropped = int(sum(len(r.dropped) for r in res.records))
    corrupt = int(sum(len(r.corrupt_ids) for r in res.records))
    degraded = int(sum(1 for r in res.records if r.degraded))
    return {"rounds": len(res.records), "all_finite": finite,
            "dropped": dropped, "corrupt": corrupt,
            "degraded_rounds": degraded, "wall_s": wall}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer rounds/trials)")
    ap.add_argument("--out", default="BENCH_faults.json")
    ap.add_argument("--max-overhead", type=float, default=0.05,
                    help="fail if robust aggregation costs more than this "
                         "fraction of the plain fused round (median wall)")
    args = ap.parse_args(argv)

    if args.smoke:
        rounds, warmup, trials, chaos_rounds = 10, 3, 10, 8
        num_devices, samples = 20, 2400
    else:
        rounds, warmup, trials, chaos_rounds = 30, 3, 40, 30
        num_devices, samples = 40, 4800

    print("== robust-aggregation overhead (fused round, no corruption) ==")
    ov = bench_overhead(num_devices, samples, rounds, warmup)
    print(f"  plain {ov['plain_round_s'] * 1e3:8.2f}ms/round  "
          f"robust {ov['robust_round_s'] * 1e3:8.2f}ms/round  "
          f"overhead {ov['overhead'] * 100:+.2f}%  "
          f"metric diff {ov['metric_max_diff']:.2e}")

    print("== fused rejection vs host reference parity ==")
    par = bench_rejection_parity(trials)
    print(f"  {par['trials']} randomized cohorts, "
          f"{par['mismatches']} mismatches")

    print("== fault-injection preset (chaos completion) ==")
    chaos = bench_chaos_preset(num_devices=60, max_rounds=chaos_rounds)
    print(f"  {chaos['rounds']} rounds in {chaos['wall_s']:.1f}s: "
          f"dropped={chaos['dropped']} corrupt={chaos['corrupt']} "
          f"degraded={chaos['degraded_rounds']} "
          f"finite={chaos['all_finite']}")

    failures = []
    if ov["overhead"] > args.max_overhead:
        failures.append(f"robust overhead {ov['overhead'] * 100:.2f}% > "
                        f"{args.max_overhead * 100:.0f}% gate")
    if ov["metric_max_diff"] > 1e-6:
        failures.append(f"robust path diverged without corruption: "
                        f"metric diff {ov['metric_max_diff']:.3e}")
    if par["mismatches"]:
        failures.append(f"rejection parity broken: {par['mismatches']} "
                        f"fused-vs-host mismatches")
    if not chaos["all_finite"]:
        failures.append("fault-injection preset produced non-finite metrics")
    if chaos["dropped"] == 0 or chaos["corrupt"] == 0:
        failures.append("fault-injection preset injected no faults "
                        f"(dropped={chaos['dropped']}, "
                        f"corrupt={chaos['corrupt']})")

    out = {"smoke": args.smoke, "overhead": ov, "rejection_parity": par,
           "chaos": chaos,
           "gate": {"max_overhead": args.max_overhead,
                    "failures": failures}}
    with open(args.out, "w") as fobj:
        json.dump(out, fobj, indent=2)
    print(f"\nwrote {args.out}")
    if failures:
        raise SystemExit("bench_faults regression gate FAILED:\n  "
                         + "\n  ".join(failures))


if __name__ == "__main__":
    main()
