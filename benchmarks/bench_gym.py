"""Scheduler-gym benchmark: env throughput and trained-vs-untrained RLDS.

Two arms, written to ``BENCH_gym.json`` (CI runs ``--smoke``):

1. **Throughput** — env steps/sec swept over E (parallel environments) x K
   (pool size), in two execution modes:

   - ``stepwise`` (E=1) — one jitted dispatch per round: the execution
     model of the sequential Python loop the gym replaces (RLDS's old
     constructor pre-training drove the simulator exactly like this).
   - ``fused`` (every E) — the gym's lax.scan-over-rounds + vmap-over-envs
     rollout in a single dispatch.

   The headline number is fused@E=max vs stepwise@E=1 at fixed K: the
   vectorized gym must amortize per-step dispatch by >=10x or it cannot
   out-collect the loop it replaces. The fused E=1 -> E=max ratio is also
   recorded (on many-core/accelerator hosts it tracks the same claim; on
   a 2-core CI box fused E=1 is already compute-bound, so the stepwise
   baseline is the meaningful one).

2. **Policy quality** — a gym-trained RLDS policy vs the untrained
   (random-init, no-pretrain) policy on paired held-out scenarios
   (identical eval seed, deterministic top-k conversion). The run FAILS
   (exit 1) if trained mean cost exceeds untrained — the regression gate
   CI enforces per PR.

  PYTHONPATH=src python -m benchmarks.bench_gym            # full sweep
  PYTHONPATH=src python -m benchmarks.bench_gym --smoke    # CI-sized
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

FULL_KS = [64, 256]
FULL_ES = [1, 4, 16, 64, 256]
SMOKE_KS = [64]
SMOKE_ES = [1, 16, 256]


def _time_loop(fn, min_s: float = 0.5, max_reps: int = 200) -> float:
    fn()  # warm-up (compile)
    reps, t0 = 0, time.perf_counter()
    while True:
        fn()
        reps += 1
        elapsed = time.perf_counter() - t0
        if elapsed >= min_s or reps >= max_reps:
            break
    return elapsed / reps


def bench_throughput(Ks, Es, rollout_len: int) -> list:
    """Environment steps/sec, same random-action workload in both modes.

    ``stepwise`` @ E=1 dispatches one jitted ``step`` per round (how the
    sequential pre-gym loop consumed the simulator); ``fused`` runs the
    whole (E, T) rollout in one scan+vmap dispatch. ``policy`` rows add
    the RLDS network in the loop (training throughput, fused only).
    """
    from repro.core.schedulers.rlds import init_policy
    from repro.gym import CURRICULA, EnvConfig, batch_reset, batch_rollout
    from repro.gym.env import (available_mask, batch_random_rollout,
                               plan_from_gumbel, release_instant, step)

    scen = CURRICULA["default"]
    params = init_policy(jax.random.PRNGKey(0))
    rows = []
    for K in Ks:
        cfg = EnvConfig(num_devices=K, num_jobs=3, n_sel=max(1, K // 10))

        # Sequential baseline: one jitted env dispatch per round, drawing a
        # random Gumbel top-k plan inside the call — the SAME per-step
        # workload the fused arm runs, minus only the scan/vmap fusion.
        state0 = batch_reset(cfg, scen, jax.random.PRNGKey(1), 1)
        state1 = jax.tree_util.tree_map(lambda x: x[0], state0)

        @jax.jit
        def stepped(s):
            key, k_plan = jax.random.split(s.key)
            s = s._replace(key=key)
            now = release_instant(cfg, s)
            plan = plan_from_gumbel(
                jnp.zeros(cfg.num_devices),
                jax.random.gumbel(k_plan, (cfg.num_devices,)),
                available_mask(s, now), cfg.n_sel)
            return step(cfg, s, plan)

        def run_stepwise():
            s = state1
            for _ in range(rollout_len):
                s, out = stepped(s)
            out.cost.block_until_ready()

        per_call = _time_loop(run_stepwise, max_reps=50)
        stepwise_sps = rollout_len / per_call
        rows.append({"K": K, "E": 1, "mode": "stepwise",
                     "rollout_len": rollout_len,
                     "env_steps_per_sec": stepwise_sps})
        print(f"  K={K:>4} E=   1 stepwise: {stepwise_sps:>10.0f} env steps/s"
              f" (sequential per-round dispatch baseline)")

        for mode, make_fn in (
                ("fused", lambda: jax.jit(
                    lambda s: batch_random_rollout(cfg, s, rollout_len))),
                ("policy", lambda: jax.jit(
                    lambda s: batch_rollout(cfg, params, s, rollout_len)))):
            for E in Es:
                roll = make_fn()
                states = batch_reset(cfg, scen, jax.random.PRNGKey(1), E)

                def run_fused():
                    _, out = roll(states)
                    out.cost.block_until_ready()

                per_call = _time_loop(run_fused, max_reps=50)
                sps = E * rollout_len / per_call
                r = {"K": K, "E": E, "mode": mode,
                     "rollout_len": rollout_len, "env_steps_per_sec": sps,
                     "scaling_vs_stepwise": sps / stepwise_sps}
                rows.append(r)
                print(f"  K={K:>4} E={E:>4} {mode:8s}: {sps:>10.0f} env "
                      f"steps/s (x{r['scaling_vs_stepwise']:.1f} vs "
                      "stepwise)")
    return rows


def bench_policy(smoke: bool) -> dict:
    from repro.core.schedulers.rlds import init_policy
    from repro.gym import TrainConfig, default_stages, evaluate, train_rlds

    tcfg = (TrainConfig(num_envs=16, rollout_len=16, iters=40)
            if smoke else TrainConfig(num_envs=32, rollout_len=32, iters=120))
    stages = default_stages("default", num_devices=(64,), num_jobs=3)
    print(f"  training: E={tcfg.num_envs} T={tcfg.rollout_len} "
          f"iters={tcfg.iters}")
    t0 = time.perf_counter()
    params, logs = train_rlds(stages, tcfg, seed=0)
    train_s = time.perf_counter() - t0

    cfg, scen = stages[0]
    untrained = init_policy(jax.random.PRNGKey(99))
    episodes, steps = (16, 32) if smoke else (32, 64)
    ev_t = evaluate(cfg, scen, params, seed=7, episodes=episodes, steps=steps)
    ev_u = evaluate(cfg, scen, untrained, seed=7, episodes=episodes,
                    steps=steps)
    improvement = ev_u["mean_cost"] / max(ev_t["mean_cost"], 1e-12)
    print(f"  trained mean_cost={ev_t['mean_cost']:.4f}  "
          f"untrained={ev_u['mean_cost']:.4f}  (x{improvement:.2f} better, "
          f"trained in {train_s:.1f}s)")
    return {
        "train_config": tcfg._asdict(), "train_wall_s": train_s,
        "train_log_head": logs[:3], "train_log_tail": logs[-3:],
        "trained_mean_cost": ev_t["mean_cost"],
        "untrained_mean_cost": ev_u["mean_cost"],
        "trained_mean_round_time": ev_t["mean_round_time"],
        "untrained_mean_round_time": ev_u["mean_round_time"],
        "improvement": improvement,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sweep (fewer E/K points, short training)")
    ap.add_argument("--out", default="BENCH_gym.json")
    ap.add_argument("--tol", type=float, default=0.0,
                    help="allowed trained/untrained cost slack "
                         "(0.0 = trained must be at least as good)")
    args = ap.parse_args(argv)

    Ks = SMOKE_KS if args.smoke else FULL_KS
    Es = SMOKE_ES if args.smoke else FULL_ES
    T = 16 if args.smoke else 32

    print(f"== gym throughput (E sweep {Es}, K sweep {Ks}) ==")
    throughput = bench_throughput(Ks, Es, T)

    # Per-K summary: E=1 (sequential per-round dispatch) -> E=max (fused
    # vmap), identical random-action env workload on both sides.
    scaling = {}
    for K in Ks:
        by = {(r["E"], r["mode"]): r["env_steps_per_sec"]
              for r in throughput if r["K"] == K}
        scaling[str(K)] = {
            "stepwise_E1": by[(1, "stepwise")],
            "fused_E1": by[(1, "fused")],
            "fused_Emax": by[(max(Es), "fused")],
            "policy_Emax": by[(max(Es), "policy")],
            "scaling_E1_to_Emax": by[(max(Es), "fused")] / by[(1, "stepwise")],
            "scaling_fused_E1_to_Emax": by[(max(Es), "fused")] / by[(1, "fused")],
        }
        print(f"  K={K}: E=1 -> E={max(Es)} env scaling "
              f"x{scaling[str(K)]['scaling_E1_to_Emax']:.1f} "
              f"(fused vmap vs per-step dispatch)")

    print("== trained vs untrained RLDS (paired held-out scenarios) ==")
    policy = bench_policy(args.smoke)

    out = {"smoke": args.smoke, "jax_backend": jax.default_backend(),
           "Ks": Ks, "Es": Es, "throughput": throughput,
           "scaling": scaling, "policy": policy}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"\nwrote {args.out}")

    # Regression gate: a gym-trained policy must not be worse than the
    # untrained one it replaces.
    limit = policy["untrained_mean_cost"] * (1.0 + args.tol)
    if policy["trained_mean_cost"] > limit:
        print(f"REGRESSION: trained mean cost {policy['trained_mean_cost']:.4f} "
              f"> untrained {policy['untrained_mean_cost']:.4f} "
              f"(tol {args.tol})", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
