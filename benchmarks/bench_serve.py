"""Online scheduler-service benchmark: incremental vs full plan rescoring.

Runs the SAME traffic trace (generated once, seeded) through two
``SchedulerService`` instances that differ only in ``rescore_mode`` and
measures per-admission decision latency (p50/p99), service throughput, and
plan-cost parity. Because both modes execute plans from the live scheduler
(rescoring is advisory), the realized round trajectories must be IDENTICAL
— the benchmark's hard parity gate — while incremental rescoring must beat
full per-arrival re-search on decision latency.

Gates (written to ``BENCH_serve.json``, enforced in CI bench-smoke):
- executed-cost parity: realized per-round costs match across modes
  (max |diff| <= 1e-9 — same plans, same rng, same trajectory);
- latency: incremental p50 * min_speedup <= full p50;
- advisory agreement: mean advisory rescore cost within ``--advisory-tol``
  relative difference (incremental scores the current plan, full searches
  a fresh one, so agreement is approximate by construction).

  PYTHONPATH=src python -m benchmarks.bench_serve            # full horizon
  PYTHONPATH=src python -m benchmarks.bench_serve --smoke    # CI-sized
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from repro.experiment.presets import get_preset
from repro.serve import SchedulerService, trace_from_spec


def run_mode(spec, trace, mode: str) -> dict:
    svc = SchedulerService(spec, rescore_mode=mode)
    report = svc.run(trace)
    lat = report.decision_latency
    advisory = [c for c in svc.rescore_costs if c > 0]
    return {
        "mode": mode,
        "p50_ms": lat["p50_s"] * 1e3,
        "p99_ms": lat["p99_s"] * 1e3,
        "decisions": lat["count"],
        "rounds": report.rounds_completed,
        "arrivals": report.arrivals,
        "readmissions": report.readmissions,
        "churn_events": report.churn_events,
        "tenant_fairness": report.tenant_fairness,
        "queue_depth_max": report.queue_depth_max,
        "mean_advisory_cost": (float(np.mean(advisory)) if advisory else 0.0),
        "realized_costs": [r.cost for r in svc.engine.records],
        "wall_s": report.wall_s,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (short horizon)")
    ap.add_argument("--scheduler", default="bods")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--min-speedup", type=float, default=1.0,
                    help="fail unless full p50 latency >= incremental p50 * "
                         "this factor (CI uses 1.0 — incremental strictly "
                         "no slower; full runs report >=2x)")
    ap.add_argument("--advisory-tol", type=float, default=0.5,
                    help="max relative difference between the modes' mean "
                         "advisory rescore costs")
    args = ap.parse_args(argv)

    preset_kwargs = ({"horizon": 12_000.0, "num_devices": 50}
                     if args.smoke else {})
    spec = get_preset("online-smoke", scheduler=args.scheduler,
                      **preset_kwargs)
    # One trace, both modes: traffic held bit-identical.
    probe = SchedulerService(spec)
    trace = trace_from_spec(spec.arrivals, len(probe.templates),
                            probe.engine.pool.num_devices)

    print(f"== scheduler service: incremental vs full rescoring "
          f"({args.scheduler}, {len(trace)} traffic events) ==")
    rows = {}
    for mode in ("incremental", "full"):
        r = run_mode(spec, trace, mode)
        rows[mode] = r
        print(f"  {mode:>11}: p50={r['p50_ms']:8.2f}ms "
              f"p99={r['p99_ms']:8.2f}ms over {r['decisions']} decisions, "
              f"{r['rounds']} rounds, advisory cost "
              f"{r['mean_advisory_cost']:.3f}")

    inc, full = rows["incremental"], rows["full"]
    failures = []

    ci, cf = inc["realized_costs"], full["realized_costs"]
    if len(ci) != len(cf):
        failures.append(f"trajectory length diverged: incremental {len(ci)} "
                        f"rounds vs full {len(cf)}")
    else:
        max_diff = (float(np.max(np.abs(np.asarray(ci) - np.asarray(cf))))
                    if ci else 0.0)
        if max_diff > 1e-9:
            failures.append(f"executed-plan cost parity broken: max realized "
                            f"cost diff {max_diff:.3e} > 1e-9")

    if inc["p50_ms"] * args.min_speedup > full["p50_ms"]:
        failures.append(
            f"incremental p50 {inc['p50_ms']:.2f}ms * "
            f"{args.min_speedup:.2f} > full p50 {full['p50_ms']:.2f}ms "
            "(incremental rescoring must not be slower than full re-search)")

    if full["mean_advisory_cost"] > 0:
        rel = (abs(inc["mean_advisory_cost"] - full["mean_advisory_cost"])
               / full["mean_advisory_cost"])
        if rel > args.advisory_tol:
            failures.append(
                f"advisory cost divergence {rel:.3f} > {args.advisory_tol}")
    else:
        rel = 0.0

    speedup = (full["p50_ms"] / inc["p50_ms"] if inc["p50_ms"] > 0 else
               float("inf"))
    print(f"  parity: realized trajectories "
          f"{'identical' if not failures or 'parity' not in failures[0] else 'DIVERGED'}, "
          f"advisory reldiff {rel:.3f}, incremental x{speedup:.2f} "
          f"faster at p50")

    # Trajectories are bulky and identical across modes — keep one copy.
    full.pop("realized_costs")
    inc["realized_cost_sum"] = float(np.sum(inc.pop("realized_costs")))
    out = {
        "smoke": args.smoke,
        "scheduler": args.scheduler,
        "traffic_events": len(trace),
        "incremental": inc,
        "full": full,
        "p50_speedup": speedup,
        "advisory_reldiff": rel,
        "gate": {"min_speedup": args.min_speedup,
                 "advisory_tol": args.advisory_tol,
                 "failures": failures},
    }
    with open(args.out, "w") as fobj:
        json.dump(out, fobj, indent=2)
    print(f"\nwrote {args.out}")
    if failures:
        raise SystemExit("bench_serve regression gate FAILED:\n  "
                         + "\n  ".join(failures))


if __name__ == "__main__":
    main()
