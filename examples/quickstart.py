"""Quickstart: schedule 3 federated jobs over 100 heterogeneous devices.

  PYTHONPATH=src python examples/quickstart.py

Runs the paper's core loop end to end in under a minute: a shared device
pool, the time+fairness cost model, and BODS vs Random scheduling — printing
the per-job time-to-target and the speedup.
"""

import numpy as np

from repro.config.base import ArchFamily, JobConfig, ModelConfig
from repro.core import CostModel, DevicePool, MultiJobEngine, get_scheduler
from repro.fl.runtime import SyntheticRuntime


def make_jobs(n=3, target=0.8):
    mc = ModelConfig(name="clf", family=ArchFamily.CNN, cnn_spec=(("flatten",),),
                     input_shape=(4, 4, 1), num_classes=10)
    return [JobConfig(job_id=i, model=mc, target_metric=target, max_rounds=150)
            for i in range(n)]


def run(scheduler: str) -> float:
    pool = DevicePool.heterogeneous(num_devices=100, num_jobs=3, seed=1)
    cost = CostModel(pool, alpha=4.0, beta=0.25)
    cost.calibrate([5.0] * 3, n_sel=10)
    engine = MultiJobEngine(
        jobs=make_jobs(),
        pool=pool,
        cost_model=cost,
        scheduler=get_scheduler(scheduler, cost_model=cost, seed=0),
        runtime=SyntheticRuntime(num_jobs=3, num_devices=100, seed=2),
        n_sel=10,
    )
    engine.run()
    makespan = max(v["makespan"] for v in engine.summary().values())
    for name, v in engine.summary().items():
        t2t = "-" if v["time_to_target"] is None else f"{v['time_to_target']/60:.0f} min"
        print(f"  [{scheduler}] {name}: best_acc={v['best_accuracy']:.3f} "
              f"time_to_target={t2t}")
    return makespan


if __name__ == "__main__":
    print("Random scheduling (FedAvg baseline):")
    t_random = run("random")
    print("BODS (Bayesian-optimization scheduling, this paper):")
    t_bods = run("bods")
    print(f"\nmakespan: random={t_random/60:.0f} min, bods={t_bods/60:.0f} min "
          f"-> {t_random/t_bods:.2f}x faster")
