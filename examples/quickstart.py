"""Quickstart: schedule 3 federated jobs over 100 heterogeneous devices.

  PYTHONPATH=src python examples/quickstart.py

Runs the paper's core loop end to end in under a minute — and shows the
repo's one front door for every scenario: a declarative ``ExperimentSpec``.
A preset materializes the spec (jobs, pool, cost model, scheduler name,
runtime kind), ``spec.run()`` wires and executes the engine, and the spec
JSON-round-trips so any run is replayable:

    spec = get_preset("quickstart", scheduler="bods")
    result = spec.run()                    # -> ExperimentResult
    spec.save("spec.json")                 # python -m repro.experiment.cli run spec.json
"""

from repro.experiment import get_preset


def run(scheduler: str) -> float:
    result = get_preset("quickstart", scheduler=scheduler).run()
    for name, v in result.summary.items():
        t2t = ("-" if v["time_to_target"] is None
               else f"{v['time_to_target']/60:.0f} min")
        print(f"  [{scheduler}] {name}: best_acc={v['best_accuracy']:.3f} "
              f"time_to_target={t2t}")
    return result.makespan


if __name__ == "__main__":
    print("Random scheduling (FedAvg baseline):")
    t_random = run("random")
    print("BODS (Bayesian-optimization scheduling, this paper):")
    t_bods = run("bods")
    print(f"\nmakespan: random={t_random/60:.0f} min, bods={t_bods/60:.0f} min "
          f"-> {t_random/t_bods:.2f}x faster")
