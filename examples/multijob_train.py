"""Real multi-job federated training (the paper's testbed in miniature).

  PYTHONPATH=src python examples/multijob_train.py [--rounds 15]

Two REAL jobs — LeNet-5 and CNN-B on synthetic prototype datasets,
partitioned non-IID exactly as the paper's §5 (2 classes/device) — train in
parallel on a shared 40-device pool under BODS. Wall-clock is simulated by
the shifted-exponential device model; the learning is real JAX training.

The whole scenario is the ``real-fl-two-job`` preset: one ``ExperimentSpec``
with ``runtime="real_fl"`` replaces the old hand-wired
DevicePool/CostModel/scheduler/runtime/engine chain.
"""

import argparse

from repro.experiment import get_preset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=15)
    ap.add_argument("--devices", type=int, default=40)
    ap.add_argument("--scheduler", default="bods")
    args = ap.parse_args()

    spec = get_preset("real-fl-two-job", scheduler=args.scheduler,
                      rounds=args.rounds, num_devices=args.devices)
    result = spec.run(verbose=True)

    print("\nsummary:")
    for name, v in result.summary.items():
        print(f"  {name}: rounds={v['rounds']} best_acc={v['best_accuracy']:.3f} "
              f"sim_time={v['makespan']/60:.1f} min")


if __name__ == "__main__":
    main()
