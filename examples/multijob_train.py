"""Real multi-job federated training (the paper's testbed in miniature).

  PYTHONPATH=src python examples/multijob_train.py [--rounds 15]

Two REAL jobs — LeNet-5 and CNN-B on synthetic prototype datasets,
partitioned non-IID exactly as the paper's §5 (2 classes/device) — train in
parallel on a shared 40-device pool under BODS. Wall-clock is simulated by
the shifted-exponential device model; the learning is real JAX training.
"""

import argparse

import numpy as np

from repro.config.base import JobConfig
from repro.configs.paper_models import cnn_b, lenet5
from repro.core import CostModel, DevicePool, MultiJobEngine, get_scheduler
from repro.data.synthetic import make_classification_dataset
from repro.fl.partition import noniid_partition
from repro.fl.runtime import FLJobRuntime, MultiRuntime


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=15)
    ap.add_argument("--devices", type=int, default=40)
    ap.add_argument("--scheduler", default="bods")
    args = ap.parse_args()

    jobs, runtimes = [], []
    for jid, (mk, target) in enumerate(((lenet5, 0.90), (cnn_b, 0.80))):
        cfg = mk()
        x, y = make_classification_dataset(8000, cfg.input_shape,
                                           cfg.num_classes, noise=1.2, seed=jid)
        ex, ey = make_classification_dataset(800, cfg.input_shape,
                                             cfg.num_classes, noise=1.2,
                                             seed=100 + jid)
        part = noniid_partition(y, args.devices, seed=jid)
        job = JobConfig(job_id=jid, model=cfg, target_metric=target,
                        max_rounds=args.rounds, local_epochs=3,
                        batch_size=32, lr=0.02)
        jobs.append(job)
        runtimes.append(FLJobRuntime(job, x, y, part, ex, ey, seed=jid))

    pool = DevicePool.heterogeneous(args.devices, len(jobs), seed=5)
    cost = CostModel(pool, alpha=4.0, beta=0.25)
    cost.calibrate([3.0] * len(jobs), n_sel=5)
    engine = MultiJobEngine(jobs, pool, cost,
                            get_scheduler(args.scheduler, cost_model=cost, seed=0),
                            MultiRuntime(runtimes), n_sel=5)
    engine.run(verbose=True)

    print("\nsummary:")
    for name, v in engine.summary().items():
        print(f"  {name}: rounds={v['rounds']} best_acc={v['best_accuracy']:.3f} "
              f"sim_time={v['makespan']/60:.1f} min")


if __name__ == "__main__":
    main()
