"""Batched LM serving with continuous batching (reduced qwen3 config).

  PYTHONPATH=src python examples/serve_batched.py [--requests 12]

Builds the decode state, runs one fused serve_step per token across all
slots, and refills finished slots from the request queue — the production
decode loop in miniature (the full-size path is exercised by the
decode_32k dry-run cells).
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.qwen3_1p7b import reduced
from repro.launch.steps import make_serve_step
from repro.models.transformer import init_decode_state, lm_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = reduced()
    params, _ = lm_init(cfg, seed=0)
    serve_step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    B = args.slots
    state = init_decode_state(cfg, B, 128)
    rng = np.random.default_rng(0)
    queue = [(int(rng.integers(0, cfg.vocab_size)), args.max_new)
             for _ in range(args.requests)]

    slot_tok = jnp.zeros((B,), jnp.int32)
    slot_left = np.zeros(B, np.int64)
    lengths = jnp.zeros((B,), jnp.int32)
    done, steps = 0, 0
    t0 = time.time()
    while done < args.requests:
        for b in range(B):
            if slot_left[b] == 0 and queue:
                tok, n = queue.pop()
                slot_tok = slot_tok.at[b].set(tok)
                slot_left[b] = n
                lengths = lengths.at[b].set(0)
        logits, state = serve_step(params, state, slot_tok, lengths)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        active = jnp.asarray(slot_left > 0)
        lengths = lengths + active
        slot_tok = jnp.where(active, nxt, slot_tok)
        steps += 1
        for b in range(B):
            if slot_left[b] > 0:
                slot_left[b] -= 1
                done += slot_left[b] == 0
    dt = time.time() - t0
    total = args.requests * args.max_new
    print(f"served {args.requests} requests ({total} tokens) in {steps} fused "
          f"steps / {dt:.2f}s -> {total/dt:.0f} tok/s on CPU "
          f"(slot utilization {total/(steps*B)*100:.0f}%)")


if __name__ == "__main__":
    main()
