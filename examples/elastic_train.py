"""Fault-tolerant LM training with injected failures + exact recovery.

  PYTHONPATH=src python examples/elastic_train.py

Trains the reduced qwen3 config while a FailureInjector kills the "job" twice;
the elastic runtime restores the latest atomic checkpoint AND the data-
pipeline cursor, so the final state matches an uninterrupted run exactly.
"""

import shutil

import jax
import jax.numpy as jnp

from repro.config.base import OptimizerConfig, TrainConfig
from repro.configs.qwen3_1p7b import reduced
from repro.launch.elastic import ElasticConfig, FailureInjector, run_elastic
from repro.launch.steps import make_train_step
from repro.launch.train import TokenBatcher
from repro.models.transformer import lm_init

CKPT = "/tmp/repro_elastic_demo"


def train(inject: bool):
    shutil.rmtree(CKPT, ignore_errors=True)
    cfg = reduced()
    tc = TrainConfig(optimizer=OptimizerConfig(name="adamw", lr=1e-3))
    step, opt_init = make_train_step(cfg, tc)
    step = jax.jit(step, donate_argnums=(0, 1))

    def make_state():
        params, _ = lm_init(cfg, seed=0)
        return (params, opt_init(params))

    def step_fn(state, batch):
        p, o = state
        p, o, m = step(p, o, batch)
        return (p, o), m

    losses = []
    out = run_elastic(
        make_state=make_state, step_fn=step_fn,
        batch_iter=TokenBatcher(cfg, batch=4, seq=64),
        num_steps=40,
        config=ElasticConfig(save_every=10, checkpoint_dir=CKPT),
        injector=FailureInjector(fail_at_steps=[15, 33]) if inject else None,
        on_step=lambda i, m: losses.append(m["loss"]))
    return out, losses


if __name__ == "__main__":
    clean, losses_c = train(inject=False)
    faulty, losses_f = train(inject=True)
    p_clean = clean["state"][0]
    p_fault = faulty["state"][0]
    diff = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree_util.tree_leaves(p_clean), jax.tree_util.tree_leaves(p_fault)))
    print(f"clean run:  final loss {losses_c[-1]:.4f}, restarts={clean['restarts']}")
    print(f"faulty run: final loss {losses_f[-1]:.4f}, restarts={faulty['restarts']}, "
          f"steps replayed={faulty['steps_replayed']}")
    print(f"max |param diff| clean vs recovered: {diff:.2e} "
          f"({'EXACT' if diff < 1e-5 else 'DIVERGED'})")
