"""Beyond-paper: the MJ-FL scheduler at datacenter scale (DESIGN.md §3).

  PYTHONPATH=src python examples/cluster_schedule.py

Schedules the 10 assigned LM architectures as concurrent TRAINING JOBS onto
a fleet of TPU slices. The mapping from the paper: devices -> pod slices
(heterogeneous generations/interference -> (a_k, mu_k)); per-job step time is
parameterized from the dry-run roofline terms when dryrun_results.json is
present (falling back to 6·N·D/peak estimates); "data fairness" -> balanced
data-shard participation per job. BODS then minimizes the same
time+fairness TotalCost — the paper's control plane, unchanged, driving an
LLM cluster.

Declaratively: each arch is a ``JobSpec`` (model resolved through the arch
registry), the per-arch step cost folds into the pool via
``PoolSpec.job_weights``, and ``spec.build()`` exposes the live engine for
the utilization readout.
"""

import json
import os

import numpy as np

from repro.config import get_arch
from repro.configs import ASSIGNED_ARCHS
from repro.experiment import ExperimentSpec, JobSpec, PoolSpec

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "dryrun_results.json")


def step_time_s(arch: str) -> float:
    """Per-step time on one slice, from the dry-run roofline if available."""
    if os.path.exists(DRYRUN):
        d = json.load(open(DRYRUN))
        rec = d.get(f"{arch}|train_4k|single")
        if rec and rec.get("status") == "ok":
            r = rec["roofline"]
            return max(r["compute_s"], r["memory_s"], r["collective_s"])
    cfg = get_arch(arch)
    return 6 * cfg.active_param_count() * 4096 * 256 / (256 * 197e12)


def main():
    archs = list(ASSIGNED_ARCHS)
    num_slices = 64  # the cluster is carved into 64 schedulable slices
    # fold the per-arch step cost into each job's data sizes: slower models
    # need proportionally more slice-seconds per scheduling quantum
    base = np.array([step_time_s(a) for a in archs])
    spec = ExperimentSpec(
        name="cluster-schedule-bods",
        jobs=tuple(JobSpec(name=a, model=a, target_metric=0.8, max_rounds=40,
                           local_epochs=1) for a in archs),
        pool=PoolSpec(num_devices=num_slices, seed=3, a_range=(8e-4, 3e-3),
                      data_range=(80, 200),
                      job_weights=tuple(base / base.mean())),
        scheduler="bods", runtime="synthetic", runtime_kwargs={"seed": 7},
        n_sel=6)
    exp = spec.build()
    result = exp.run()
    engine = exp.engine

    print(f"{'job (arch)':20s} {'rounds':>6s} {'slice-hours':>12s} {'makespan_h':>11s}")
    for name, v in result.summary.items():
        print(f"{name:20s} {v['rounds']:6d} {v['total_round_time']*6/3600:12.2f} "
              f"{v['makespan']/3600:11.2f}")
    util = engine.counts.sum() / (num_slices * result.makespan /
        np.mean([r.round_time for r in result.records]))
    print(f"\ncluster slice utilization proxy: {util*100:.0f}% "
          f"({len(result.records)} scheduling decisions)")


if __name__ == "__main__":
    main()
