"""Beyond-paper: the MJ-FL scheduler at datacenter scale (DESIGN.md §3).

  PYTHONPATH=src python examples/cluster_schedule.py

Schedules the 10 assigned LM architectures as concurrent TRAINING JOBS onto
a fleet of TPU slices. The mapping from the paper: devices -> pod slices
(heterogeneous generations/interference -> (a_k, mu_k)); per-job step time is
parameterized from the dry-run roofline terms when dryrun_results.json is
present (falling back to 6·N·D/peak estimates); "data fairness" -> balanced
data-shard participation per job. BODS then minimizes the same
time+fairness TotalCost — the paper's control plane, unchanged, driving an
LLM cluster.
"""

import json
import os

import numpy as np

from repro.config import get_arch
from repro.config.base import ArchFamily, JobConfig
from repro.configs import ASSIGNED_ARCHS
from repro.core import CostModel, DevicePool, MultiJobEngine, get_scheduler
from repro.fl.runtime import SyntheticRuntime

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "dryrun_results.json")


def step_time_s(arch: str) -> float:
    """Per-step time on one slice, from the dry-run roofline if available."""
    if os.path.exists(DRYRUN):
        d = json.load(open(DRYRUN))
        rec = d.get(f"{arch}|train_4k|single")
        if rec and rec.get("status") == "ok":
            r = rec["roofline"]
            return max(r["compute_s"], r["memory_s"], r["collective_s"])
    cfg = get_arch(arch)
    return 6 * cfg.active_param_count() * 4096 * 256 / (256 * 197e12)


def main():
    archs = list(ASSIGNED_ARCHS)
    num_slices = 64  # the cluster is carved into 64 schedulable slices
    jobs = []
    for i, arch in enumerate(archs):
        cfg = get_arch(arch)
        jobs.append(JobConfig(job_id=i, model=cfg, target_metric=0.8,
                              max_rounds=40, local_epochs=1))

    pool = DevicePool.heterogeneous(num_slices, len(jobs), seed=3,
                                    a_range=(8e-4, 3e-3), data_range=(80, 200))
    # fold the per-arch step cost into each job's data sizes: slower models
    # need proportionally more slice-seconds per scheduling quantum
    base = np.array([step_time_s(a) for a in archs])
    pool.data_sizes = pool.data_sizes * (base / base.mean())[None, :]

    cost = CostModel(pool, alpha=4.0, beta=0.25)
    cost.calibrate([1.0] * len(jobs), n_sel=6)
    engine = MultiJobEngine(
        jobs, pool, cost, get_scheduler("bods", cost_model=cost, seed=0),
        SyntheticRuntime(num_jobs=len(jobs), num_devices=num_slices, seed=7),
        n_sel=6)
    engine.run()

    print(f"{'job (arch)':20s} {'rounds':>6s} {'slice-hours':>12s} {'makespan_h':>11s}")
    for name, v in engine.summary().items():
        print(f"{name:20s} {v['rounds']:6d} {v['total_round_time']*6/3600:12.2f} "
              f"{v['makespan']/3600:11.2f}")
    util = engine.counts.sum() / (num_slices * max(
        v['makespan'] for v in engine.summary().values()) /
        np.mean([r.round_time for r in engine.records]))
    print(f"\ncluster slice utilization proxy: {util*100:.0f}% "
          f"({len(engine.records)} scheduling decisions)")


if __name__ == "__main__":
    main()
